"""Sharded-backend scaling benchmark (extension: multi-device traversal).

Measures the ``sharded`` TraversalEngine backend against the
single-device ``xla_coo`` sweep on one synthetic ER graph:

  * ``fig_sharded/native_bfs`` — the ``xla_coo`` baseline per query;
  * ``fig_sharded/sharded_bfs/n=N`` — the sharded backend pinned to an
    N-wide mesh, for every N in {1, 2, 4} that the visible device count
    allows. ``derived`` carries the speedup vs the N=1 sharded point
    (the 1->N scaling curve).

The stored-threshold gate quantity is the **N=1 overhead ratio**:
``sharded@1 / xla_coo`` measured interleaved (``time_pair``), i.e. what
the partitioned layout + shard_map dispatch cost when sharding buys no
parallelism. Sharding must not regress the single-device path:
``benchmarks.run`` (and the standalone ``main``) writes
``BENCH_sharded.json`` and FAILS when the ratio exceeds
``REPRO_SHARDED_OVERHEAD_MAX`` (default 2.0) — shard_map's fixed
dispatch overhead is real at CPU-CI graph sizes, but bounded; on
HBM-scale graphs it amortizes to noise.

The record also carries ``warm_zero_repacks``: the measured (warm)
phase must hit the per-(epoch, shard) pack cache and the module-level
trace cache exclusively — zero shard re-partitions, zero re-traces.

CI runs this under ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
(the ``sharded`` stage) so the scaling curve has three points; a plain
``bench`` run degenerates to the N=1 gate, which is the part that guards
the single-device trajectory.
"""
from __future__ import annotations

import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graphview import build_graph_view
from repro.core.table import Table
from repro.core.traversal_engine import TraversalEngine

from .common import time_call, time_pair

OVERHEAD_THRESHOLD = 2.0  # stored threshold: sharded@1 vs xla_coo
RECORD_PATH = "BENCH_sharded.json"

#: last run's record, consumed by benchmarks.run (or main) for the JSON gate
RECORD = None


def _graph(v, e, seed=0):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, v, e).astype(np.int32)
    dst = rng.integers(0, v, e).astype(np.int32)
    vt = Table.create("V", {"vid": np.arange(v, dtype=np.int32)})
    et = Table.create("E", {"src": src, "dst": dst,
                            "w": rng.random(e).astype(np.float32) + 0.1})
    return build_graph_view("G", vt, et, v_id="vid", e_src="src", e_dst="dst")


def run(quick: bool = False):
    global RECORD
    v = 1 << 14 if quick else 1 << 17
    e = 4 * v
    s = 32
    max_hops = 8
    view = _graph(v, e)
    rng = np.random.default_rng(1)
    sp = jnp.asarray(rng.integers(0, v, s), jnp.int32)

    n_dev = jax.device_count()
    widths = [n for n in (1, 2, 4) if n <= n_dev]
    engines = {n: TraversalEngine(n_devices=n) for n in widths}
    baseline = TraversalEngine()

    rows = []
    # gate quantity: interleaved mins, both sides share the estimator
    t_sharded1, t_native = time_pair(
        lambda: engines[1].bfs(view, sp, max_hops=max_hops,
                               backend="sharded"),
        lambda: baseline.bfs(view, sp, max_hops=max_hops,
                             backend="xla_coo"),
    )
    ratio = t_sharded1 / t_native
    rows.append(("fig_sharded/native_bfs", t_native, f"V={v} E={e} S={s}"))
    rows.append(("fig_sharded/sharded_bfs/n=1", t_sharded1,
                 f"overhead={ratio:.2f}x"))

    scaling = {1: round(t_sharded1, 1)}
    for n in widths[1:]:
        t = time_call(
            engines[n].bfs, view, sp, max_hops=max_hops, backend="sharded")
        scaling[n] = round(t, 1)
        rows.append((f"fig_sharded/sharded_bfs/n={n}", t,
                     f"speedup_vs_n1={t_sharded1 / t:.2f}x"))

    # warm phase: repeated queries must re-pack and re-trace nothing
    eng = engines[widths[-1]]
    packs = eng.stats["shard_pack_builds"]
    traces = eng.stats["traces_bfs_sharded"]
    eng.bfs(view, sp, max_hops=max_hops, backend="sharded")
    eng.bfs(view, sp, max_hops=max_hops, backend="sharded")
    warm_zero = (
        eng.stats["shard_pack_builds"] == packs
        and eng.stats["traces_bfs_sharded"] == traces
        and eng.stats["shard_pack_hits"] >= 2
    )
    rows.append(("fig_sharded/warm_zero_repacks", 0.0, warm_zero))

    RECORD = {
        "n1_overhead_ratio": round(ratio, 4),
        "native_us": round(t_native, 1),
        "scaling_us": {str(k): val for k, val in scaling.items()},
        "warm_zero_repacks": bool(warm_zero),
        "devices": n_dev,
        "lanes": s,
        "quick": quick,
    }
    return rows


def publish(record, failures=0) -> int:
    """Write BENCH_sharded.json and apply the stored-threshold gate.
    Returns the updated failure count (shared by run.py and main)."""
    threshold = float(
        os.environ.get("REPRO_SHARDED_OVERHEAD_MAX", OVERHEAD_THRESHOLD)
    )
    record = dict(record, threshold=threshold)
    path = os.environ.get("REPRO_BENCH_SHARDED_JSON", RECORD_PATH)
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.write("\n")
    print(
        f"sharded/overhead,0.0,ratio={record['n1_overhead_ratio']:.2f}x "
        f"(threshold {threshold:.2f}x) -> {path}",
        flush=True,
    )
    if record["n1_overhead_ratio"] > threshold:
        print(
            f"sharded/REGRESSION,0.0,N=1 overhead "
            f"{record['n1_overhead_ratio']:.2f}x exceeds stored threshold "
            f"{threshold:.2f}x",
            flush=True,
        )
        failures += 1
    if not record["warm_zero_repacks"]:
        print(
            "sharded/REGRESSION,0.0,warm queries re-packed or re-traced "
            "instead of hitting the per-(epoch, shard) caches",
            flush=True,
        )
        failures += 1
    return failures


def main() -> None:
    quick = os.environ.get("REPRO_BENCH_QUICK", "0") == "1"
    print("name,us_per_call,derived")
    rows = run(quick=quick)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if publish(RECORD):
        sys.exit(1)


if __name__ == "__main__":
    main()
