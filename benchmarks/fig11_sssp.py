"""Fig 11 (Appendix D): SSSP with filtering predicates — native SPScan
(frontier Bellman-Ford) vs. Grail-style vertex-centric iterative SQL.
Distances are cross-checked for equality on the selected sub-graph.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from repro.baselines.grail import grail_sssp
from repro.core import traversal as T
from repro.core.engine import GRFusion
from repro.core.graphview import build_graph_view
from repro.core.query import Query, P, col
from repro.core.table import Table
from repro.data.synthetic import graph_tables, random_graph

from .common import time_call


def run(quick: bool = False):
    # road-network-like: low, near-uniform degree
    V, E = (2_000, 6_000) if quick else (10_000, 30_000)
    sels = [25] if quick else [10, 25, 50]
    iters = 24 if quick else 48
    g = random_graph(V, E, kind="uniform", seed=13)
    vd, ed = graph_tables(g)
    vt, et = Table.create("V", vd), Table.create("E", ed)
    view = build_graph_view("G", vt, et, v_id="vid", e_src="src", e_dst="dst")
    w = jnp.asarray(ed["weight"])
    sel = jnp.asarray(ed["sel"])

    # plan-IR path: SHORTESTPATH hint -> physical SPScan over the predicate
    # sub-graph, planned once per selectivity and re-executed
    eng = GRFusion()
    eng.create_table("V", vd)
    eng.create_table("E", ed)
    eng.create_graph_view("G", vertexes="V", edges="E", v_id="vid",
                          e_src="src", e_dst="dst")

    rows = []
    for s in sels:
        mask = sel < s
        native = functools.partial(
            T.sssp, view, jnp.array([0], jnp.int32), weight_by_row=w,
            edge_mask_by_row=mask, max_iters=iters, block_size=1 << 15,
        )
        us_nat = time_call(native)
        base = functools.partial(
            grail_sssp, et, "src", "dst", "weight", jnp.int32(0), mask,
            n_vertices=V, n_iters=iters, capacity=1 << 16,
        )
        us_grail = time_call(base)

        dn = np.asarray(native()[0][0])
        dg = np.asarray(base())
        fin = np.isfinite(dn) & np.isfinite(dg)
        assert (np.isfinite(dn) == np.isfinite(dg)).all()
        assert np.abs(dn[fin] - dg[fin]).max() < 1e-3

        rows.append((f"fig11/native_spscan/sel={s}%", us_nat, "sssp-us"))
        rows.append(
            (f"fig11/grail_iterative/sel={s}%", us_grail, f"speedup={us_grail/us_nat:.1f}x")
        )

        RS = P("RS")
        prepared = eng.prepare(
            Query().from_paths("G", "RS")
            .hint_shortest_path("weight")
            .where((RS.start.id == 0) & (RS.edges[0:"*"].attr("sel") < s))
            .select(dist=col("RS.distance"), end=col("RS.endvertexid"))
        )
        us_plan = time_call(prepared.run)
        r = prepared.run()
        # the engine's SPScan runs to its own iteration budget, so reached
        # counts can only match or exceed the truncated native sweep
        assert r.count >= int(np.isfinite(dn).sum()), "plan-IR SPScan lost vertices"
        rows.append(
            (f"fig11/planned_spscan/sel={s}%", us_plan, f"reached={r.count}")
        )
    return rows
