"""Fig 13 (extension): continuous-batching serving-loop latency.

Closed-loop discrete-event benchmark for ``repro.serve.loop.QueryLoop``:
a fixed-QPS arrival process drives a parameterized 2-hop neighborhood
query (one structural shape, bind values rotating over the four
highest-degree sources) through the loop's admission path — shared
shape-keyed plan cache, deadline-based adaptive flush, per-ticket bind.

Time is hybrid: arrivals and flush deadlines live on a **virtual
microsecond clock** (deterministic spacing at the offered QPS, no
sleeping), while each ``pump()`` runs with the clock in real-time mode so
measured execution cost advances the same timeline. Queueing delay,
deadline waits, and service time therefore land in one latency
distribution; ``Ticket.latency_us`` is read straight off the tickets.

Reported rows:

  * ``serving_cold/first_flush`` — first ticket end-to-end (plan build +
    predicate compile + deadline wait): the admission-miss worst case;
  * ``serving_warm/qps=Q`` — steady-state p50 (``us`` column) and p99
    (``derived``) after a warm-up phase, measured over ``n_req`` arrivals;
  * ``direct_warm`` — one warm ``bind().execute()`` with no loop, the
    service-time floor;
  * ``serving_ratio`` — p99 / (flush_deadline + direct): the stored-
    threshold gate quantity. A deadline-flushed request ideally waits one
    deadline then pays one service; the ratio is machine-normalized, so
    the gate catches loop-scheduling regressions rather than host speed.

The module also records ``RECORD`` (consumed by ``benchmarks.run`` into
``BENCH_serving.json``), including ``warm_cache_hits_only``: during the
measured phase the shared plan's ``PlanRuntime.stats`` must move only on
``*_hits`` counters and the plan cache must report zero new builds — the
paper-level acceptance that warm steady-state serving re-plans and
re-compiles nothing.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.engine import GRFusion
from repro.core.query import P, Query, param
from repro.serve.loop import QueryLoop

from .common import time_call

#: last run's serving record, consumed by benchmarks.run for the JSON gate
RECORD = None


class SimClock:
    """Virtual microsecond clock with a real-time passthrough window.

    Between events the benchmark sets the time explicitly
    (``advance_to``); around ``pump()`` it calls ``start``/``stop`` so
    wall time spent executing accrues onto the virtual timeline and into
    every ticket's ``done_us``.
    """

    def __init__(self):
        self.sim = 0.0
        self._anchor = None

    def __call__(self) -> float:
        if self._anchor is None:
            return self.sim
        return self.sim + (time.perf_counter() - self._anchor) * 1e6

    def advance_to(self, t_us: float) -> None:
        self.sim = max(self.sim, t_us)

    def start(self) -> None:
        self._anchor = time.perf_counter()

    def stop(self) -> None:
        self.sim = self()
        self._anchor = None


def _neighborhood_query():
    PS = P("PS")
    return (
        Query()
        .from_paths("G", "PS")
        .where((PS.start.id == param("src")) & (PS.length <= 2))
        .select(e=PS.end.id)
    )


def _offered_load(loop, clk, query, srcs, n_req: int, interval_us: float):
    """Inject n_req arrivals at fixed spacing; pump at flush instants."""

    def service():
        clk.start()
        try:
            loop.pump()
        finally:
            clk.stop()

    tickets = []
    base = clk.sim
    for i in range(n_req):
        arrival = base + i * interval_us
        while True:
            due = loop.next_due()
            if due is None or due > arrival:
                break
            clk.advance_to(due)
            service()
        clk.advance_to(arrival)
        tickets.append(loop.submit(query, src=srcs[i % len(srcs)]))
        if loop.pending >= loop.lane_width:
            service()
    while loop.pending:
        due = loop.next_due()
        if due is not None:
            clk.advance_to(due)
        service()
    return tickets


def run(quick: bool = False):
    global RECORD
    V, E = (2_000, 8_000) if quick else (10_000, 40_000)
    n_warm = 20 if quick else 40
    n_req = 60 if quick else 200
    qps = 100
    lane, deadline_us = 8, 2_000.0

    from repro.data.synthetic import graph_tables, random_graph

    g = random_graph(V, E, kind="powerlaw", seed=11)
    vd, ed = graph_tables(g)
    eng = GRFusion()
    eng.create_table("V", vd)
    eng.create_table("E", ed)
    eng.create_graph_view(
        "G", vertexes="V", edges="E", v_id="vid", e_src="src", e_dst="dst"
    )
    deg = np.bincount(np.asarray(ed["src"]), minlength=V)
    srcs = [int(x) for x in np.argsort(-deg)[:4]]

    clk = SimClock()
    loop = QueryLoop(
        eng, lane_width=lane, flush_deadline_us=deadline_us, clock=clk
    )
    interval_us = 1e6 / qps

    # cold phase: the first flush pays plan build + predicate compile
    cold = _offered_load(loop, clk, _neighborhood_query(), srcs,
                         n_warm, interval_us)
    assert all(t.status == "done" for t in cold)
    cold_first_us = cold[0].latency_us

    # steady state: snapshot the shared plan's runtime stats, then measure
    prepared = eng.plan_cache.get_or_prepare(
        eng.query_shape(_neighborhood_query()),
        lambda: (_ for _ in ()).throw(
            AssertionError("warm shape must already be cached")
        ),
    )
    rt_before = dict(prepared.runtime.stats)
    plan_builds = eng.plan_cache.stats["plan_builds"]
    warm = _offered_load(loop, clk, _neighborhood_query(), srcs,
                         n_req, interval_us)
    assert all(t.status == "done" for t in warm)
    delta = {
        k: v - rt_before.get(k, 0)
        for k, v in prepared.runtime.stats.items()
        if v != rt_before.get(k, 0)
    }
    hits_only = (
        bool(delta)
        and all(k.endswith("hits") for k in delta)
        and eng.plan_cache.stats["plan_builds"] == plan_builds
    )

    lat = np.array([t.latency_us for t in warm])
    p50, p99 = (float(np.percentile(lat, q)) for q in (50, 99))
    direct_us = time_call(
        lambda: prepared.bind(src=srcs[0]).execute().count
    )
    ratio = p99 / (deadline_us + direct_us)

    RECORD = {
        "qps": qps,
        "n_requests": n_req,
        "lane_width": lane,
        "flush_deadline_us": deadline_us,
        "p50_us": round(p50, 1),
        "p99_us": round(p99, 1),
        "direct_us": round(direct_us, 1),
        "cold_first_us": round(cold_first_us, 1),
        "ratio": round(ratio, 4),
        "warm_cache_hits_only": hits_only,
        "quick": quick,
    }
    return [
        ("fig13/serving_cold/first_flush", cold_first_us,
         "plan+compile+deadline"),
        (f"fig13/serving_warm/qps={qps}", p50, f"p99={p99:.1f}us"),
        ("fig13/direct_warm", direct_us, "bind+execute, no loop"),
        ("fig13/serving_ratio", 0.0,
         f"p99/(deadline+direct)={ratio:.3f} hits_only={hits_only}"),
    ]
