"""Fig 9: reachability with filtering predicates vs. edge selectivity.

The sub-graph is selected by an edge predicate (`sel < s` = s% of edges, the
paper's synthesized-attribute control). Native pushes the mask into the
frontier sweep; SQLGraph filters the edge relation then joins. The paper's
headline: changing selectivity 5%->50% costs SQLGraph 138x vs GRFusion 1.72x
(Fig 9b); we report the same sensitivity ratio.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from repro.baselines.sqlgraph import reachability_joins
from repro.core import traversal as T
from repro.core.engine import GRFusion
from repro.core.graphview import build_graph_view
from repro.core.query import Query, P, col
from repro.core.table import Table
from repro.data.synthetic import graph_tables, random_graph

from .common import time_call, time_pair


def run(quick: bool = False):
    V, E = (5_000, 25_000) if quick else (20_000, 100_000)
    S = 32
    L = 4 if quick else 8
    sels = [5, 25] if quick else [5, 10, 25, 50]
    g = random_graph(V, E, kind="powerlaw", seed=11)
    vd, ed = graph_tables(g)
    vt, et = Table.create("V", vd), Table.create("E", ed)
    view = build_graph_view("G", vt, et, v_id="vid", e_src="src", e_dst="dst")

    rng = np.random.default_rng(3)
    js = jnp.asarray(rng.integers(0, V, S).astype(np.int32))
    jt = jnp.asarray(rng.integers(0, V, S).astype(np.int32))
    sel_col = jnp.asarray(ed["sel"])

    # plan-IR path: the optimizer pushes the selectivity predicate into the
    # frontier sweep's uniform edge mask (§6.2) from the declarative form
    eng = GRFusion()
    eng.create_table("V", vd)
    eng.create_table("E", ed)
    eng.create_graph_view("G", vertexes="V", edges="E", v_id="vid",
                          e_src="src", e_dst="dst")
    eng.create_table(
        "Pairs",
        {"src": np.asarray(js), "dst": np.asarray(jt)},
        capacity=S,
    )

    rows = []
    per_sel = {}
    for s in sels:
        mask = sel_col < s
        native = functools.partial(
            T.bfs, view, js, edge_mask_by_row=mask, target_pos=jt,
            max_hops=L, block_size=1 << 15,
        )
        fcap = 1
        while fcap < min(S * V, 1 << 20):
            fcap <<= 1
        base = functools.partial(
            reachability_joins, et, "src", "dst", js, jt, mask,
            n_hops=L, frontier_capacity=fcap,
        )
        # min-estimated like us_nat (time_pair): like-for-like speedups
        us_join = time_call(base, agg="min")
        _, join_ovf = base()

        PS = P("PS")
        prepared = eng.prepare(
            Query().from_table("Pairs", "Q").from_paths("G", "PS")
            .where((PS.start.id == col("Q.src")) & (PS.end.id == col("Q.dst"))
                   & (PS.edges[0:"*"].attr("sel") < s))
            .hint_max_length(L)
            .select(hops=col("PS.length"))
        )
        # interleaved raw-vs-planned timing: see fig8 / BENCH_plan_overhead
        us_nat, us_plan = time_pair(native, prepared.run)
        per_sel[s] = (us_nat, us_join)
        rows.append((f"fig9/native_bfs/sel={s}%", us_nat / S, "per-query-us"))
        r = prepared.run()
        d = np.asarray(native())
        dt = d[np.arange(S), np.asarray(jnp.clip(jt, 0, V - 1))]
        assert r.count == int((dt >= 1).sum()), "plan-IR reach count mismatch"
        rows.append((f"fig9/planned_bfs/sel={s}%", us_plan / S, "per-query-us"))
        note = "DNF(intermediate-overflow)" if bool(join_ovf) else f"speedup={us_join/us_nat:.1f}x"
        rows.append((f"fig9/sqlgraph_joins/sel={s}%", us_join / S, note))
    lo, hi = min(sels), max(sels)
    nat_ratio = per_sel[hi][0] / per_sel[lo][0]
    join_ratio = per_sel[hi][1] / per_sel[lo][1]
    rows.append(
        (
            f"fig9/sensitivity_{lo}to{hi}",
            0.0,
            f"native={nat_ratio:.2f}x join={join_ratio:.2f}x (paper: 1.72x vs 138x)",
        )
    )
    return rows
