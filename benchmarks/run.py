"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``REPRO_BENCH_QUICK=1`` runs
reduced sizes. Roofline numbers (§Roofline) come from the dry-run
(``python -m repro.launch.dryrun``), not from here — this file is the
paper-experiment reproduction on CPU.

After the figure modules run, the harness derives the **plan-overhead
record**: for every fig8/fig9 point that has both a ``native_*`` (raw
traversal kernel) and a ``planned_*`` (full plan-IR prepared-plan path)
row, the planned/native ratio is written to ``BENCH_plan_overhead.json``
at the repo root. The compiled query runtime's contract is that prepared
plans add at most ``REPRO_PLAN_OVERHEAD_MAX`` (default 1.3x, the stored
threshold) on top of the raw kernels at S=32 lanes; the bench stage FAILS
when the worst ratio regresses above the threshold, so the perf
trajectory accumulates and is enforced from this PR on.

The fig13 module additionally publishes a **serving record**
(``BENCH_serving.json``): warm p50/p99 latency of the continuous-batching
``QueryLoop`` at a fixed offered QPS, gated two ways — the
machine-normalized ratio ``p99 / (flush_deadline + direct_execute)`` must
stay under ``REPRO_SERVING_P99_MAX`` (default 3.0, the stored threshold),
and the warm steady state must have executed purely from caches
(``warm_cache_hits_only``: PlanRuntime moved only on ``*_hits`` counters,
zero new plan builds).

The fig_sharded module publishes the **sharded-backend record**
(``BENCH_sharded.json``): the N=1 overhead ratio of the ``sharded``
traversal backend vs ``xla_coo`` (gated by ``REPRO_SHARDED_OVERHEAD_MAX``
— partitioning must not regress the single-device path), the 1->N
scaling curve at whatever device counts are visible, and
``warm_zero_repacks`` (warm queries hit the per-(epoch, shard) pack and
trace caches exclusively).

The fig_ingest module publishes the **streaming-ingest record**
(``BENCH_ingest.json``): bulk-load edges/sec to the first correct query,
per-batch insert p50/p99 (the p99 is the compaction stall), and warm-query
latency during sustained writes — gated by ``REPRO_INGEST_QUERY_MAX``
(under-writes / quiescent warm-query ratio; delta inserts must leave the
packing caches warm) plus the ``warm_zero_repacks`` and
``first_query_correct`` hard gates.
"""
from __future__ import annotations

import json
import os
import re
import sys
import traceback

from .common import emit

PLAN_OVERHEAD_THRESHOLD = 1.3  # stored threshold: planned vs raw, S=32 lanes
PLAN_OVERHEAD_PATH = "BENCH_plan_overhead.json"

SERVING_THRESHOLD = 3.0  # stored threshold: p99 / (deadline + direct exec)
SERVING_PATH = "BENCH_serving.json"


def plan_overhead_record(rows, threshold: float, quick: bool) -> dict:
    """Planned-vs-native per-query ratios for fig8/fig9 points."""
    by_name = {name: us for name, us, _ in rows}
    ratios = {}
    for name, us in by_name.items():
        m = re.match(r"(fig[89])/planned_(\w+)/(.+)", name)
        if not m:
            continue
        fig, kind, point = m.groups()
        native = by_name.get(f"{fig}/native_{kind}/{point}")
        if native:
            ratios[f"{fig}/{point}"] = round(us / native, 4)
    return {
        "ratios": ratios,
        "max_ratio": round(max(ratios.values()), 4) if ratios else None,
        "threshold": threshold,
        "lanes": 32,
        "quick": quick,
    }


def main() -> None:
    quick = os.environ.get("REPRO_BENCH_QUICK", "0") == "1"
    from . import (
        fig8_reachability,
        fig9_selectivity,
        fig10_triangles,
        fig11_sssp,
        fig12_pathjoin,
        fig13_serving,
        fig_ingest,
        fig_sharded,
        table1_construction,
    )

    mods = [
        ("fig8", fig8_reachability),
        ("fig9", fig9_selectivity),
        ("fig10", fig10_triangles),
        ("fig11", fig11_sssp),
        ("fig12", fig12_pathjoin),
        ("fig13", fig13_serving),
        ("fig_sharded", fig_sharded),
        ("fig_ingest", fig_ingest),
        ("table1", table1_construction),
    ]
    print("name,us_per_call,derived")
    failures = 0
    all_rows = []
    for name, mod in mods:
        try:
            rows = mod.run(quick=quick)
            emit(rows)
            all_rows.extend(rows)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)

    threshold = float(
        os.environ.get("REPRO_PLAN_OVERHEAD_MAX", PLAN_OVERHEAD_THRESHOLD)
    )
    record = plan_overhead_record(all_rows, threshold, quick)
    out_path = os.environ.get("REPRO_BENCH_JSON", PLAN_OVERHEAD_PATH)
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.write("\n")
    if record["ratios"]:
        print(
            f"plan_overhead/max,0.0,ratio={record['max_ratio']:.2f}x "
            f"(threshold {threshold:.2f}x) -> {out_path}",
            flush=True,
        )
        if record["max_ratio"] > threshold:
            print(
                f"plan_overhead/REGRESSION,0.0,max ratio "
                f"{record['max_ratio']:.2f}x exceeds stored threshold "
                f"{threshold:.2f}x",
                flush=True,
            )
            failures += 1

    srv_threshold = float(
        os.environ.get("REPRO_SERVING_P99_MAX", SERVING_THRESHOLD)
    )
    srv = getattr(fig13_serving, "RECORD", None)
    if srv is not None:
        srv = dict(srv, threshold=srv_threshold)
        srv_path = os.environ.get("REPRO_BENCH_SERVING_JSON", SERVING_PATH)
        with open(srv_path, "w") as f:
            json.dump(srv, f, indent=2, sort_keys=True)
            f.write("\n")
        print(
            f"serving/p99,0.0,ratio={srv['ratio']:.2f}x "
            f"(threshold {srv_threshold:.2f}x) -> {srv_path}",
            flush=True,
        )
        if srv["ratio"] > srv_threshold:
            print(
                f"serving/REGRESSION,0.0,p99 ratio {srv['ratio']:.2f}x "
                f"exceeds stored threshold {srv_threshold:.2f}x",
                flush=True,
            )
            failures += 1
        if not srv["warm_cache_hits_only"]:
            print(
                "serving/REGRESSION,0.0,warm steady state re-planned or "
                "re-built instead of hitting caches",
                flush=True,
            )
            failures += 1
    if getattr(fig_sharded, "RECORD", None) is not None:
        failures = fig_sharded.publish(fig_sharded.RECORD, failures)
    if getattr(fig_ingest, "RECORD", None) is not None:
        failures = fig_ingest.publish(fig_ingest.RECORD, failures)

    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
