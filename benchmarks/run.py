"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``REPRO_BENCH_QUICK=1`` runs
reduced sizes. Roofline numbers (§Roofline) come from the dry-run
(``python -m repro.launch.dryrun``), not from here — this file is the
paper-experiment reproduction on CPU.
"""
from __future__ import annotations

import os
import sys
import traceback

from .common import emit


def main() -> None:
    quick = os.environ.get("REPRO_BENCH_QUICK", "0") == "1"
    from . import (
        fig8_reachability,
        fig9_selectivity,
        fig10_triangles,
        fig11_sssp,
        table1_construction,
    )

    mods = [
        ("fig8", fig8_reachability),
        ("fig9", fig9_selectivity),
        ("fig10", fig10_triangles),
        ("fig11", fig11_sssp),
        ("table1", table1_construction),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in mods:
        try:
            emit(mod.run(quick=quick))
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
