"""Fig 12 (extension): stacked-PATHS queries that previously could not run.

Before the path–path hash join operator, a stacked PATHS source had to be
start-anchored on a column of the plan below it; end-only and const-start
cross references raised NotImplementedError at plan time. This figure
measures exactly those queries — the "meet in the middle" form: paths
fanning out from two different source vertices that end at the same
vertex, joined on their end-vertex lanes:

    FROM G.PATHS P1, G.PATHS P2
    WHERE P1.StartVertex.Id = s1 AND P2.StartVertex.Id = s2
      AND P2.EndVertex.Id = P1.EndVertex.Id
      AND P1.Length <= L AND P2.Length <= L

Reported per length bound: the prepared-plan serving path (plan once,
re-execute; the PathJoin's joined-batch cache is invalidated per call via
a topology-epoch bump so every rep pays the real join, not a cache
replay), plus the globally-simple variant (distinct-vertices rewrite —
cross-path vertex-disjointness filtered above the join). ``derived``
carries the surviving row count, so the trajectory also tracks result
stability.
"""
from __future__ import annotations

import numpy as np

from repro.core.compiled import table_key
from repro.core.engine import GRFusion
from repro.core.query import Query, P

from .common import time_call


def run(quick: bool = False):
    V, E = (2_000, 8_000) if quick else (10_000, 40_000)
    lengths = [1, 2] if quick else [1, 2, 3]
    from repro.data.synthetic import graph_tables, random_graph

    g = random_graph(V, E, kind="powerlaw", seed=11)
    vd, ed = graph_tables(g)
    eng = GRFusion()
    eng.create_table("V", vd)
    eng.create_table("E", ed)
    eng.create_graph_view(
        "G", vertexes="V", edges="E", v_id="vid", e_src="src", e_dst="dst"
    )

    # two well-connected sources (highest fan-out) so the join is non-empty
    deg = np.bincount(np.asarray(ed["src"]), minlength=V)
    s1, s2 = (int(x) for x in np.argsort(-deg)[:2])

    rows = []
    for L in lengths:
        P1, P2 = P("P1"), P("P2")
        base = (
            Query()
            .from_paths("G", "P1")
            .from_paths("G", "P2")
            .where(
                (P1.start.id == s1) & (P1.length <= L)
                & (P2.start.id == s2) & (P2.length <= L)
                & (P2.end.id == P1.end.id)
            )
            .select(meet=P1.end.id)
        )
        prepared = eng.prepare(base)

        def call(prep=prepared):
            # bump the vertex-table epoch so the PathJoin's joined-batch
            # cache misses: each rep pays the real traversals + hash join
            # (the topology epoch stays put — the packed edge stream is
            # reused, as on the attribute-update serving path)
            eng.epochs.bump(table_key("V"))
            return prep.execute().count

        us = time_call(call)
        n = int(prepared.execute().count)
        rows.append((f"fig12/pathjoin_meet/L={L}", us, f"rows={n}"))

        Pd1, Pd2 = P("P1"), P("P2")
        q_distinct = (
            Query()
            .from_paths("G", "P1")
            .from_paths("G", "P2")
            .where(
                (Pd1.start.id == s1) & (Pd1.length <= L)
                & (Pd2.start.id == s2) & (Pd2.length <= L)
                & (Pd2.end.id == Pd1.end.id)
            )
            .distinct_vertices()
            .select(meet=Pd1.end.id)
        )
        prepared_d = eng.prepare(q_distinct)

        def call_d(prep=prepared_d):
            eng.epochs.bump(table_key("V"))
            return prep.execute().count

        us_d = time_call(call_d)
        n_d = int(prepared_d.execute().count)
        rows.append(
            (f"fig12/pathjoin_meet_distinct/L={L}", us_d, f"rows={n_d}")
        )
        assert n_d <= n, "disjointness filter can only remove rows"

        # warm prepared-plan replay: nothing changed between calls, so the
        # epoch-keyed joined-batch cache answers without re-traversing
        us_warm = time_call(lambda prep=prepared: prep.execute().count)
        rows.append(
            (f"fig12/pathjoin_meet_warm/L={L}", us_warm, f"rows={n}")
        )
    return rows
