#!/usr/bin/env bash
# Tier-1 CI, split into named stages with per-stage timing. Run from the
# repo root:  bash scripts/ci.sh [stage ...]
#
# Stages (default: all, in order):
#   collect       pytest collection only — fails fast on import/collection
#                 errors before any slow work starts
#   tier1         fast test suite (slow dry-run compiles and multi-device
#                 sharded suite excluded)
#   differential  cross-backend traversal equivalence suite (-m differential)
#   sharded       multi-device sharded-backend suite: the differential
#                 family sweep plus the >=1M-vertex bit-identity tests
#                 (-m "differential or sharded") re-run under
#                 XLA_FLAGS=--xla_force_host_platform_device_count=N for
#                 N=1,2,4, then the fig_sharded scaling benchmark at 4
#                 forced devices (writes BENCH_sharded.json, gated)
#   bench         quick-size benchmark smoke (REPRO_BENCH_QUICK=1); writes
#                 BENCH_plan_overhead.json (planned-vs-raw fig8/fig9 ratios),
#                 BENCH_serving.json (fig13 QueryLoop warm p50/p99 at
#                 fixed QPS), and BENCH_sharded.json (sharded-backend N=1
#                 overhead + scaling curve) at the repo root and FAILS if
#                 any regresses past its stored threshold
#                 (REPRO_PLAN_OVERHEAD_MAX, 1.3; REPRO_SERVING_P99_MAX,
#                 3.0; REPRO_SHARDED_OVERHEAD_MAX, 2.0) or a warm steady
#                 state stops running purely from caches
#   ingest        write-heavy path: the mutating differential family
#                 (tests/differential/test_write_heavy.py — seeded
#                 insert/tombstone/query/compact interleavings, four
#                 backends + a mutation-log oracle) plus the streaming
#                 ingest benchmark (writes BENCH_ingest.json; gated on
#                 warm-query-under-writes ratio, zero re-packs from
#                 delta inserts, and first-query correctness)
#   chaos         fault-injection suite (tests/robust, -m chaos): backend
#                 failover bit-identity, the crash-point sweep over every
#                 registered injection site vs the mutation-log oracle,
#                 serving-loop hardening (deadlines / retry / circuit
#                 breaker), ingest quarantine, and the disabled-injector
#                 zero-overhead pins; the crash sweep + failover files
#                 re-run at 2 forced host devices so the sharded
#                 backend's failover and shard-pack seams are exercised
#                 multi-device
#   analyze       static analysis — hot-path lint over src/repro against
#                 scripts/lint_baseline.json (python -m repro.analysis);
#                 fails on any fresh host-sync / device-loop /
#                 structural-repr / pump-alloc /
#                 cross-shard-host-transfer finding
#   docs          executes the README's worked example
#                 (examples/readme_example.py, asserted output) so the
#                 documented API can never drift from the code
#
# The full suite including slow markers is:  python -m pytest -q
set -euo pipefail
cd "$(dirname "$0")/.."

STAGES=("$@")
if [ ${#STAGES[@]} -eq 0 ]; then
  STAGES=(collect tier1 differential sharded ingest chaos analyze bench docs)
fi

declare -a TIMINGS=()

run_stage() {
  local name="$1"; shift
  echo "== stage: ${name} =="
  local t0 t1
  t0=$(date +%s)
  "$@"
  t1=$(date +%s)
  TIMINGS+=("${name}: $((t1 - t0))s")
  echo "== stage ${name} OK in $((t1 - t0))s =="
}

bench_stage() {
  # runs inside run_stage so the cat of the records counts toward the
  # stage and a missing record file fails the stage itself
  env REPRO_BENCH_QUICK=1 PYTHONPATH=src python -m benchmarks.run
  echo "-- plan overhead record --"
  cat BENCH_plan_overhead.json
  echo "-- serving record --"
  cat BENCH_serving.json
  echo "-- sharded record --"
  cat BENCH_sharded.json
  echo "-- ingest record --"
  cat BENCH_ingest.json
}

ingest_stage() {
  # the write-heavy differential family on its own (it also rides the
  # differential and sharded sweeps), then the streaming-ingest benchmark
  # with its stored-threshold + hard gates
  python -m pytest -q tests/differential/test_write_heavy.py
  env REPRO_BENCH_QUICK=1 PYTHONPATH=src python -m benchmarks.fig_ingest
  cat BENCH_ingest.json
}

chaos_stage() {
  # the full fault-injection suite on the default host topology (1
  # device), then the crash-point sweep and failover family again at 2
  # forced host devices — XLA fixes the device count at process start,
  # so the multi-device run is its own pytest process
  python -m pytest -q -m chaos
  echo "-- chaos: crash sweep + failover at 2 forced host devices --"
  env XLA_FLAGS="--xla_force_host_platform_device_count=2" \
    python -m pytest -q tests/robust/test_crash_sweep.py \
      tests/robust/test_failover.py
}

sharded_stage() {
  # XLA fixes the device count at process start, so each forced count is
  # its own pytest process; the family sweep (-m differential, which now
  # includes the sharded backend) and the >=1M-vertex suite (-m sharded)
  # must be bit-identical at every width
  local n
  for n in 1 2 4; do
    echo "-- sharded: forced host device count ${n} --"
    env XLA_FLAGS="--xla_force_host_platform_device_count=${n}" \
      python -m pytest -q -m "differential or sharded"
  done
  echo "-- sharded: scaling benchmark (4 forced devices) --"
  env XLA_FLAGS="--xla_force_host_platform_device_count=4" \
    REPRO_BENCH_QUICK=1 PYTHONPATH=src python -m benchmarks.fig_sharded
  cat BENCH_sharded.json
}

# "${ARR[@]}" on an empty array trips `set -u` before bash 4.4; the
# ${ARR[@]+...} guards keep stage-less / timing-less runs working there.
for stage in ${STAGES[@]+"${STAGES[@]}"}; do
  case "$stage" in
    collect)
      # collection errors (bad imports, syntax) abort the run immediately
      run_stage collect python -m pytest -q --collect-only -m "not slow"
      ;;
    tier1)
      run_stage tier1 python -m pytest -q \
        -m "not slow and not differential and not sharded and not chaos"
      ;;
    differential)
      run_stage differential python -m pytest -q -m differential
      ;;
    sharded)
      run_stage sharded sharded_stage
      ;;
    ingest)
      run_stage ingest ingest_stage
      ;;
    chaos)
      run_stage chaos chaos_stage
      ;;
    analyze)
      run_stage analyze env PYTHONPATH=src python -m repro.analysis
      ;;
    bench)
      run_stage bench bench_stage
      ;;
    docs)
      # the README's worked example, extracted verbatim and asserted —
      # documentation drift fails CI
      run_stage docs env PYTHONPATH=src python examples/readme_example.py
      ;;
    *)
      echo "unknown stage: ${stage}" >&2
      exit 2
      ;;
  esac
done

echo "CI OK — stage timings:"
for t in ${TIMINGS[@]+"${TIMINGS[@]}"}; do
  echo "  ${t}"
done
