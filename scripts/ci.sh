#!/usr/bin/env bash
# Tier-1 CI: fast test suite (slow dry-run compiles excluded) plus a quick
# benchmark smoke. Run from the repo root:  bash scripts/ci.sh
# The full suite including slow markers is:  python -m pytest -q
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1 tests (slow excluded) =="
python -m pytest -q -m "not slow"

echo "== benchmark smoke (quick sizes) =="
REPRO_BENCH_QUICK=1 PYTHONPATH=src python -m benchmarks.run

echo "CI OK"
