"""Embed the generated dry-run/roofline tables into EXPERIMENTS.md."""
import io, os, sys, contextlib
sys.path.insert(0, "src")
from repro.roofline import report

buf = io.StringIO()
with contextlib.redirect_stdout(buf):
    sys.argv = ["report"]
    report.main()
tables = buf.getvalue()

with open("EXPERIMENTS.md") as f:
    md = f.read()

marker = "\n---\n\n## Generated tables\n"
if marker in md:
    md = md.split(marker)[0]
md += marker + "\n" + tables + "\n"
with open("EXPERIMENTS.md", "w") as f:
    f.write(md)
with open("results/report.md", "w") as f:
    f.write(tables)
print("EXPERIMENTS.md updated;", len(tables.splitlines()), "table lines")
