"""The README's worked example, executable — `scripts/ci.sh docs` runs it.

Keep this file and the "Worked example" section of README.md in sync: the
CI docs stage exists precisely so the documented API can never drift from
the code. Every printed claim is also asserted.

    PYTHONPATH=src python examples/readme_example.py
"""
import numpy as np

from repro.core.engine import GRFusion
from repro.core.query import Query, P, col, param


def main():
    eng = GRFusion()

    # -- relational sources (paper Fig. 3) --------------------------------
    eng.create_table("Users", {
        "uId": np.array([1, 2, 3, 4, 5]),
        "fName": np.array(["Edy", "Jones", "Bill", "Ann", "Cara"]),
        "Job": np.array(["Lawyer", "Doctor", "Lawyer", "Eng", "Eng"]),
    }, capacity=16)
    # capacity reserves slots for online inserts (tables are fixed-width
    # device buffers; see docs/architecture.md)
    eng.create_table("Relationships", {
        "relId": np.array([1, 2, 3, 4]),
        "uId1": np.array([1, 2, 3, 4]),
        "uId2": np.array([3, 3, 4, 5]),
        "startDate": np.array([20090110, 20081231, 20100101, 19990101]),
    }, capacity=16)

    # -- CREATE UNDIRECTED GRAPH VIEW ... (paper Listing 1) ---------------
    eng.create_graph_view(
        "SocialNetwork", vertexes="Users", edges="Relationships",
        v_id="uId", e_src="uId1", e_dst="uId2",
        e_attrs={"sDate": "startDate"},
        directed=False,
    )

    # -- run: friends-of-friends of lawyers (paper Listing 2) -------------
    PS = P("PS")
    fof = (Query()
           .from_table("Users", "U")
           .from_paths("SocialNetwork", "PS")
           .where((col("U.Job") == "Lawyer")
                  & (PS.start.id == col("U.uId"))
                  & (PS.length == 2)
                  & (PS.edges[0:"*"].attr("sDate") > 20000101))
           .select(lawyer=col("U.fName"), fof=PS.end.id))
    r = eng.run(fof)
    rows = sorted((str(a), int(b))
                  for a, b in zip(r.columns["lawyer"], r.columns["fof"]))
    print("friends-of-friends:", rows)
    # Edy(1) reaches 2 and 4 via 3; Bill(3)'s 2-hop paths all need the
    # 1999 edge 4-5, which the sDate filter prunes
    assert rows == [("Edy", 2), ("Edy", 4)], rows

    # -- explain: the typed plan, no execution ----------------------------
    plan = eng.explain(fof)
    text = plan.pretty()
    print("\nEXPLAIN:")
    print(text)
    assert "PathScanExec" in text and "TableScanExec" in text
    assert "rule path-length-inference" in text

    # -- PathJoin: two PATHS sources joining on endpoint ids --------------
    # Paths from Edy (1) and from Jones (2) that END at the same vertex —
    # an end-only cross reference no traversal can seed; the optimizer
    # plans a hash join of the two path sets' end-vertex lanes instead.
    P1, P2 = P("P1"), P("P2")
    meet = (Query()
            .from_paths("SocialNetwork", "P1")
            .from_paths("SocialNetwork", "P2")
            .where((P1.start.id == 1) & (P1.length == 1)
                   & (P2.start.id == 2) & (P2.length == 1)
                   & (P2.end.id == P1.end.id))
            .select(meet=P1.end.id))
    mplan = eng.explain(meet)
    print("\nPathJoin EXPLAIN:")
    print(mplan.pretty())
    assert "PathJoinExec" in mplan.pretty()
    assert any(e.rule == "path-join" for e in mplan.trace)
    m = eng.run(meet)
    meets = sorted(int(x) for x in m.columns["meet"])
    print("meeting vertices:", meets)
    assert meets == [3], meets  # 1-3 and 2-3 meet at vertex 3

    # -- prepare + bind: plan once, re-bind parameters, re-execute --------
    reach = (Query()
             .from_paths("SocialNetwork", "PS")
             .where((PS.start.id == param("src")) & (PS.length <= 2))
             .select(end=PS.end.id))
    prepared = eng.prepare(reach)
    ends_from_1 = sorted(set(map(int, prepared.bind(src=1).execute().columns["end"])))
    ends_from_5 = sorted(set(map(int, prepared.bind(src=5).execute().columns["end"])))
    print("\nreachable<=2 from 1:", ends_from_1, " from 5:", ends_from_5)
    assert ends_from_1 == [2, 3, 4] and ends_from_5 == [3, 4]

    # prepared plans see live updates (delta insert, no re-planning)
    eng.insert("Relationships", {
        "relId": np.array([99]), "uId1": np.array([5]), "uId2": np.array([1]),
        "startDate": np.array([20230101]),
    })
    ends_after = sorted(set(map(int, prepared.bind(src=5).execute().columns["end"])))
    print("after edge 5-1 insert, from 5:", ends_after)
    assert 1 in ends_after

    # -- serving loop: continuous batching over one shared engine ---------
    # requests bucket by structural plan shape (bind values excluded); each
    # shape plans once into the engine-wide cache, each ticket re-binds.
    # Buckets flush when a lane fills or a deadline expires; results are
    # identical to running the query directly.
    loop = eng.serving_loop(lane_width=8, flush_deadline_us=1000.0)
    t1 = loop.submit(reach, src=1)
    t5 = loop.submit(reach, src=5)
    loop.drain()
    served = sorted(set(map(int, t1.result.columns["end"])))
    print("served reachable<=2 from 1:", served)
    assert t1.status == t5.status == "done"
    direct = sorted(set(map(int, prepared.bind(src=1).execute().columns["end"])))
    assert served == direct, (served, direct)

    # -- IngestPipeline: declarative bulk loads ---------------------------
    # CSV/JSON/record/columnar payloads chunk through the SAME
    # transactional insert path (delta buffers, scheduled merge
    # compaction); the report's event diff shows what the load cost.
    from repro.data.ingest import IngestPipeline, IngestSchema, SourceSpec

    schema = IngestSchema(edges=(SourceSpec(
        "Relationships",
        {"relId": "rel", "uId1": "a", "uId2": "b", "startDate": "since"}),))
    csv_batch = "rel,a,b,since\n7,2,5,20210301\n8,4,1,20210401\n"
    report = IngestPipeline(eng, schema, chunk_rows=64).run(
        {"Relationships": csv_batch})
    print("\ningest report:", report.rows, dict(report.events))
    assert report.rows == {"Relationships": 2}
    assert report.events["delta_inserts"] >= 1  # stayed on the delta path
    assert report.events["compactions_full"] == 0
    ends_from_2 = sorted(set(map(int, prepared.bind(src=2).execute().columns["end"])))
    print("after bulk load, reachable<=2 from 2:", ends_from_2)
    assert 5 in ends_from_2  # the freshly ingested 2-5 edge is queryable

    # -- graceful degradation: backend failover under injected faults -----
    # the traversal backends are bit-identical by construction, so a query
    # whose backend dies falls down the failover chain (ending at the
    # reference backend) without changing its answer — and the result
    # says it degraded. `fault_scope` activates a seeded/scheduled fault
    # plan lexically; with no plan active the seams cost nothing.
    from repro.robust import faults
    from repro.robust.faults import FaultPlan

    linked = (Query()
              .from_paths("SocialNetwork", "PS")
              .where((PS.start.id == 1) & (PS.end.id == 4))
              .select(exists=col("PS.exists"), hops=col("PS.length"))
              .limit(1))
    clean = eng.run(linked)
    assert clean.degraded_backend is None
    with faults.fault_scope(FaultPlan({"traversal.dispatch.xla_coo": "*"})):
        degraded = eng.run(linked)  # engine's default backend is dead
    print("\nbackend dead, degraded to:", degraded.degraded_backend)
    assert degraded.degraded_backend == "reference"
    assert degraded.rows() == clean.rows()  # same bytes, worse backend
    assert eng.events["traversal_failovers"] >= 1

    print("\nreadme example OK")


if __name__ == "__main__":
    main()
