"""Quickstart: graphs as first-class citizens in a relational engine.

Builds the paper's Fig-3/4 social network, creates an UNDIRECTED graph view
(Listing 1), and runs the paper's flagship queries through cross-data-model
operator trees: vertex scan (Listing 5), friends-of-friends (Listing 2),
reachability with LIMIT 1 (Listing 3), shortest path on a sub-graph
(Listings 6/8), and an online update (§3.3).

Every query is also shown through ``GRFusion.explain(query)`` — the typed
physical plan: PathScan sits in the same operator tree as scans/joins, and
the printed form names each optimizer rewrite rule that shaped it.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.engine import GRFusion
from repro.core.query import Query, P, col


def main():
    eng = GRFusion()

    # relational sources (paper Fig. 3)
    eng.create_table("Users", {
        "uId": np.array([1, 2, 3, 4, 5]),
        "fName": np.array(["Edy", "Jones", "Bill", "Ann", "Cara"]),
        "lName": np.array(["Smith", "Parker", "Patrick", "May", "Jones"]),
        "dob": np.array([19710925, 19801121, 19760201, 19900101, 19850505]),
        "Job": np.array(["Lawyer", "Doctor", "Lawyer", "Eng", "Eng"]),
    }, capacity=16)
    eng.create_table("Relationships", {
        "relId": np.array([1, 2, 3, 4]),
        "uId1": np.array([1, 2, 3, 4]),
        "uId2": np.array([3, 3, 4, 5]),
        "startDate": np.array([20090110, 20081231, 20100101, 19990101]),
        "isRelative": np.array([1, 0, 0, 1]),
    }, capacity=64)

    # CREATE UNDIRECTED GRAPH VIEW SocialNetwork ... (Listing 1)
    eng.create_graph_view(
        "SocialNetwork", vertexes="Users", edges="Relationships",
        v_id="uId", e_src="uId1", e_dst="uId2",
        v_attrs={"lstName": "lName", "birthdate": "dob", "Job": "Job"},
        e_attrs={"sDate": "startDate", "relative": "isRelative"},
        directed=False,
    )

    # Listing 5: vertex scan with FanOut (graph-only attribute)
    r = eng.run(
        Query().from_vertexes("SocialNetwork", "VS")
        .where(col("VS.lName") == "Smith")
        .select(birthdate=col("VS.dob"), fanOut=col("VS.fanout"))
    )
    print("Listing 5 (vertexes of Smiths):", r.rows())

    # Listing 2: friends-of-friends of lawyers over recent relationships.
    # The TableScan(Users) and the PathScan compose in ONE operator tree;
    # the optimizer pushes the Job filter into the scan, infers the length
    # bound [2, 2] (§6.1), and pushes the sDate predicate into the
    # traversal's per-hop edge masks (§6.2).
    PS = P("PS")
    q2 = (Query().from_table("Users", "U").from_paths("SocialNetwork", "PS")
          .where((col("U.Job") == "Lawyer")
                 & (PS.start.id == col("U.uId"))
                 & (PS.length == 2)
                 & (PS.edges[0:"*"].attr("sDate") > 20000101))
          .select(lawyer=col("U.fName"), fof=PS.end.attr("lstName")))
    print("\nListing 2 EXPLAIN:")
    print(eng.explain(q2).pretty())
    r = eng.run(q2)
    print("Listing 2 (friends-of-friends):", r.rows())

    # Listing 3: reachability, LIMIT 1 — the physical-pathscan rule picks
    # the frontier-BFS fast path because both path ends are anchored
    q3 = (Query().from_table("Users", "A").from_table("Users", "B")
          .from_paths("SocialNetwork", "PS")
          .where((col("A.fName") == "Edy") & (col("B.fName") == "Cara")
                 & (PS.start.id == col("A.uId")) & (PS.end.id == col("B.uId")))
          .select(hops=col("PS.length")).limit(1))
    print("\nListing 3 EXPLAIN:")
    print(eng.explain(q3).pretty())
    r = eng.run(q3)
    print("Listing 3 (Edy ->* Cara):", r.rows())

    # Listings 6/8: SHORTESTPATH hint + sub-graph predicate -> SPScan
    eng.create_table("Locs", {"lid": np.arange(5)})
    eng.create_table("Roads", {
        "rid": np.arange(6),
        "s": np.array([0, 0, 1, 2, 3, 1]), "d": np.array([1, 2, 2, 3, 4, 4]),
        "dist": np.array([1.0, 4.0, 1.0, 1.0, 5.0, 10.0]),
        "spd": np.array([60, 20, 60, 60, 60, 60]),
    })
    eng.create_graph_view("RoadNet", vertexes="Locs", edges="Roads",
                          v_id="lid", e_src="s", e_dst="d")
    RS = P("RS")
    q6 = (Query().from_paths("RoadNet", "RS")
          .hint_shortest_path("dist")
          .where((RS.start.id == 0) & (RS.end.id == 4)
                 & (RS.edges[0:"*"].attr("spd") > 30))
          .select(d=col("RS.distance"), length=col("RS.length")))
    print("\nListing 6/8 EXPLAIN:")
    print(eng.explain(q6).pretty())
    r = eng.run(q6)
    print("Listing 6/8 (shortest path, spd > 30):", r.rows())

    # two PATHS sources in one query: stacked PathScan plan nodes — the
    # second traversal seeds from the first one's end vertices (§5.3)
    P1, P2 = P("P1"), P("P2")
    qq = (Query()
          .from_paths("SocialNetwork", "P1").from_paths("SocialNetwork", "P2")
          .where((P1.start.id == 1) & (P1.length == 1)
                 & (P2.start.id == P1.end.id) & (P2.length == 1))
          .select(mid=P1.end.id, end=P2.end.id))
    r = eng.run(qq)
    print("\ntwo stacked PATHS sources:", r.rows())

    # §3.3 online update: a new relationship shortens the path. A prepared
    # plan is optimized once and re-executed against live catalog state.
    prepared = eng.prepare(q3)
    eng.insert("Relationships", {
        "relId": np.array([99]), "uId1": np.array([1]), "uId2": np.array([5]),
        "startDate": np.array([20240101]), "isRelative": np.array([0]),
    })
    r = prepared.run()
    print("after online insert (prepared plan, no re-planning):", r.rows())

    # Compiled runtime + parameter binding: prepare(...).bind(...) plans and
    # compiles ONCE — predicates lower to fused column programs whose masks
    # are cached on the plan keyed by table epoch — then rebinding anchor
    # ids re-executes with zero re-planning and warm masks.
    from repro.core.query import param
    reach = eng.prepare(
        Query().from_paths("SocialNetwork", "PS")
        .where((PS.start.id == param("src")) & (PS.end.id == param("dst")))
        .select(hops=col("PS.length"))
    )
    print("\nparameterized prepared plan (compiled runtime):")
    for src, dst in [(1, 5), (2, 4), (1, 4)]:
        rr = reach.bind(src=src, dst=dst).execute()
        hops = int(rr.columns["hops"][0]) if rr.count else None
        print(f"  {src} ->* {dst}: hops={hops}")
    st = reach.runtime.stats
    print(
        f"  mask cache: {st['mask_builds']} build(s), "
        f"{st['mask_hits']} hit(s) across 3 executions "
        "(masks rebuilt only when a table epoch or bound value changes)"
    )


if __name__ == "__main__":
    main()
