"""Quickstart: graphs as first-class citizens in a relational engine.

Builds the paper's Fig-3/4 social network, creates an UNDIRECTED graph view
(Listing 1), and runs the paper's flagship queries through cross-data-model
query pipelines: vertex scan (Listing 5), friends-of-friends (Listing 2),
reachability with LIMIT 1 (Listing 3), and an online update (§3.3).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.engine import GRFusion
from repro.core.query import Query, P, col


def main():
    eng = GRFusion()

    # relational sources (paper Fig. 3)
    eng.create_table("Users", {
        "uId": np.array([1, 2, 3, 4, 5]),
        "fName": np.array(["Edy", "Jones", "Bill", "Ann", "Cara"]),
        "lName": np.array(["Smith", "Parker", "Patrick", "May", "Jones"]),
        "dob": np.array([19710925, 19801121, 19760201, 19900101, 19850505]),
        "Job": np.array(["Lawyer", "Doctor", "Lawyer", "Eng", "Eng"]),
    }, capacity=16)
    eng.create_table("Relationships", {
        "relId": np.array([1, 2, 3, 4]),
        "uId1": np.array([1, 2, 3, 4]),
        "uId2": np.array([3, 3, 4, 5]),
        "startDate": np.array([20090110, 20081231, 20100101, 19990101]),
        "isRelative": np.array([1, 0, 0, 1]),
    }, capacity=64)

    # CREATE UNDIRECTED GRAPH VIEW SocialNetwork ... (Listing 1)
    eng.create_graph_view(
        "SocialNetwork", vertexes="Users", edges="Relationships",
        v_id="uId", e_src="uId1", e_dst="uId2",
        v_attrs={"lstName": "lName", "birthdate": "dob", "Job": "Job"},
        e_attrs={"sDate": "startDate", "relative": "isRelative"},
        directed=False,
    )

    # Listing 5: vertex scan with FanOut (graph-only attribute)
    r = eng.run(
        Query().from_vertexes("SocialNetwork", "VS")
        .where(col("VS.lName") == "Smith")
        .select(birthdate=col("VS.dob"), fanOut=col("VS.fanout"))
    )
    print("Listing 5 (vertexes of Smiths):", r.rows())

    # Listing 2: friends-of-friends of lawyers over recent relationships
    PS = P("PS")
    r = eng.run(
        Query().from_table("Users", "U").from_paths("SocialNetwork", "PS")
        .where((col("U.Job") == "Lawyer")
               & (PS.start.id == col("U.uId"))
               & (PS.length == 2)
               & (PS.edges[0:"*"].attr("sDate") > 20000101))
        .select(lawyer=col("U.fName"), fof=PS.end.attr("lstName"))
    )
    print("Listing 2 (friends-of-friends):", r.rows())
    print("  plan:", "; ".join(r.explain))

    # Listing 3: reachability, LIMIT 1 -> frontier-BFS fast path
    r = eng.run(
        Query().from_table("Users", "A").from_table("Users", "B")
        .from_paths("SocialNetwork", "PS")
        .where((col("A.fName") == "Edy") & (col("B.fName") == "Cara")
               & (PS.start.id == col("A.uId")) & (PS.end.id == col("B.uId")))
        .select(hops=col("PS.length")).limit(1)
    )
    print("Listing 3 (Edy ->* Cara):", r.rows(), "via", r.explain[1])

    # §3.3 online update: a new relationship shortens the path (delta buffer,
    # no topology rebuild)
    eng.insert("Relationships", {
        "relId": np.array([99]), "uId1": np.array([1]), "uId2": np.array([5]),
        "startDate": np.array([20240101]), "isRelative": np.array([0]),
    })
    r = eng.run(
        Query().from_table("Users", "A").from_table("Users", "B")
        .from_paths("SocialNetwork", "PS")
        .where((col("A.fName") == "Edy") & (col("B.fName") == "Cara")
               & (PS.start.id == col("A.uId")) & (PS.end.id == col("B.uId")))
        .select(hops=col("PS.length")).limit(1)
    )
    print("after online insert:", r.rows())


if __name__ == "__main__":
    main()
