"""End-to-end serving driver (the paper's kind of system serves queries).

Builds a 50k-vertex power-law social graph inside the engine, then serves
three batched workloads through the cross-model pipeline:

  1. a stream of reachability queries (QueryServer: one frontier sweep
     answers a whole lane of queries),
  2. filtered shortest-path queries (SPScan over a predicate sub-graph),
  3. labeled triangle counting at several selectivities,

and finally exercises online updates while serving.

    PYTHONPATH=src python examples/graph_analytics_serving.py
"""
import time

import numpy as np

from repro.core.engine import GRFusion
from repro.core.query import Query, P, col
from repro.data.synthetic import graph_tables, random_graph
from repro.serve.engine import QueryServer


def main():
    V, E = 50_000, 250_000
    g = random_graph(V, E, kind="powerlaw", seed=42)
    vd, ed = graph_tables(g)

    eng = GRFusion()
    eng.create_table("V", vd)
    eng.create_table("E", ed, capacity=E + 4096)
    t0 = time.perf_counter()
    eng.create_graph_view("G", vertexes="V", edges="E",
                          v_id="vid", e_src="src", e_dst="dst")
    print(f"graph view over {V} vertices / {E} edges built in "
          f"{time.perf_counter()-t0:.2f}s (single pass, Table-1 style)")

    # -- workload 1: batched reachability ---------------------------------
    srv = QueryServer(eng, "G", lane_width=64, max_hops=10)
    rng = np.random.default_rng(0)
    n_q = 256
    for _ in range(n_q):
        srv.submit(int(rng.integers(0, V)), int(rng.integers(0, V)))
    t0 = time.perf_counter()
    res = srv.flush()
    dt = time.perf_counter() - t0
    reach = sum(r["reachable"] for r in res)
    print(f"reachability: {n_q} queries in {dt*1e3:.1f} ms "
          f"({dt/n_q*1e6:.0f} us/query), {reach} reachable")

    # -- workload 2: filtered shortest path (Listing 6/8 pattern) ---------
    # planned once through the rule pipeline (see the printed operator
    # tree), then the physical plan is re-executed without re-planning
    RS = P("RS")
    q_sp = (
        Query().from_paths("G", "RS")
        .hint_shortest_path("weight")
        .where((RS.start.id == 0) & (RS.end.id == int(rng.integers(1, V)))
               & (RS.edges[0:"*"].attr("sel") < 50))
        .select(dist=col("RS.distance"), hops=col("RS.length"))
    )
    prepared = eng.prepare(q_sp)
    print(prepared.pretty())
    t0 = time.perf_counter()
    r = prepared.run()
    print(f"shortest path on 50% sub-graph: {r.rows()} "
          f"({(time.perf_counter()-t0)*1e3:.1f} ms)")
    t0 = time.perf_counter()
    prepared.run()
    print(f"  re-served from the prepared plan in "
          f"{(time.perf_counter()-t0)*1e3:.1f} ms (no re-planning)")

    # -- workload 3: labeled triangles vs selectivity ----------------------
    Pp = P("T")
    for sel in (10, 50):
        q = (Query().from_paths("G", "T")
             .hint_traversal("bfs")
             .where((Pp.length == 3) & (Pp.end.id == Pp.start.id)
                    & (Pp.edges[0].attr("label") == 0)
                    & (Pp.edges[1].attr("label") == 1)
                    & (Pp.edges[2].attr("label") == 2)
                    & (Pp.edges[0:"*"].attr("sel") < sel))
             .select_count("n"))
        t0 = time.perf_counter()
        r = eng.run(q)
        print(f"labeled triangles @ sel {sel}%: {int(r.columns['n'])} "
              f"({(time.perf_counter()-t0)*1e3:.1f} ms)")

    # -- online updates while serving (§3.3) -------------------------------
    eng.insert("E", {
        "eid": np.arange(E, E + 8), "src": np.zeros(8, np.int64),
        "dst": rng.integers(0, V, 8),
        "weight": np.ones(8, np.float32),
        "sel": np.zeros(8, np.int64), "label": np.zeros(8, np.int64),
    })
    for _ in range(32):
        srv.submit(0, int(rng.integers(0, V)))
    res = srv.flush()
    print(f"after online inserts: {sum(r['reachable'] for r in res)}/32 "
          "reachable from the hub vertex")


if __name__ == "__main__":
    main()
