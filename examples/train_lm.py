"""Train a ~100M-parameter LM for a few hundred steps through the full
substrate: WSD/cosine schedule, microbatch accumulation, async checkpoints,
fault-tolerant loop (with one injected failure to show the restart path).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import time

import jax

from repro.data.pipeline import lm_batch_fn
from repro.models.common import count_params
from repro.models.transformer import LMConfig, init_params, loss_fn
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import FaultTolerantLoop, InjectedFailure
from repro.train.optimizer import AdamWConfig, init_state
from repro.train.trainer import build_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    # ~100M params: 12L x 768 (GQA kv=4), vocab 32k — tinyllama's family
    cfg = LMConfig(name="lm100m", n_layers=12, d_model=768, n_heads=12,
                   n_kv_heads=4, d_head=64, d_ff=2048, vocab=32000)
    params = init_params(jax.random.PRNGKey(0), cfg)
    print(f"params: {count_params(params)/1e6:.1f}M")

    ocfg = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps,
                       schedule="cosine")
    opt_state = init_state(params, ocfg)
    step = jax.jit(build_train_step(lambda p, b: loss_fn(p, b, cfg), ocfg,
                                    microbatches=2))
    batches = lm_batch_fn(cfg.vocab, args.batch, args.seq, seed=0)

    ckpt = CheckpointManager("results/ckpt/example_lm", keep=2)
    injected = {args.steps // 2: True}

    def failure_hook(s):
        if injected.pop(s, None):
            print(f"  !! injecting failure at step {s} (watch the resume)")
            raise InjectedFailure(str(s))

    loop = FaultTolerantLoop(step, ckpt, checkpoint_every=50,
                             failure_hook=failure_hook)
    t0 = time.perf_counter()
    params, opt_state, final = loop.run(params, opt_state, batches, args.steps)
    dt = time.perf_counter() - t0

    hist = loop.logger.history
    print(f"steps: {final}  restarts: {loop.restarts}  wall: {dt:.1f}s")
    print(f"loss: {hist[0][1]:.3f} -> {hist[-1][1]:.3f}")
    for s, l, _ in hist[:: max(len(hist) // 10, 1)]:
        print(f"  step {s:4d}  loss {l:.3f}")


if __name__ == "__main__":
    main()
