"""Sampled-minibatch GNN training: the minibatch_lg pipeline end to end.

A 20k-node power-law graph, the fanout neighbor sampler (GraphSAGE-style,
the engine's CSR as the sampling index), and GatedGCN training on the
sampled blocks — the engine's graph view and the GNN share one substrate.

    PYTHONPATH=src python examples/train_gnn_sampled.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graphview import build_graph_view
from repro.core.table import Table
from repro.data.sampler import NeighborSampler
from repro.data.synthetic import graph_tables, random_graph
from repro.models.gnn import gatedgcn
from repro.train.optimizer import AdamWConfig, init_state
from repro.train.trainer import build_train_step


def main():
    V, E = 20_000, 120_000
    g = random_graph(V, E, kind="powerlaw", seed=3)
    vd, ed = graph_tables(g)
    vt, et = Table.create("V", vd), Table.create("E", ed)
    view = build_graph_view("G", vt, et, v_id="vid", e_src="src", e_dst="dst")
    print(f"graph view: {V} vertices, {E} edges, avg fan-out "
          f"{float(view.avg_fan_out):.1f}")

    # the paper's traversal index doubles as the sampling index
    sampler = NeighborSampler(np.asarray(view.out_offsets),
                              np.asarray(view.out_dst), seed=0)

    d_feat, n_classes, fanouts, batch = 32, 8, [10, 5], 64
    feats = np.random.default_rng(0).normal(size=(V, d_feat)).astype(np.float32)
    labels = (feats @ np.random.default_rng(1).normal(size=(d_feat,)) > 0)

    cfg = gatedgcn.GatedGCNConfig(n_layers=4, d_hidden=64, d_in=d_feat,
                                  n_classes=n_classes)
    params = gatedgcn.init_params(jax.random.PRNGKey(0), cfg)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=200,
                       weight_decay=0.0)
    opt_state = init_state(params, ocfg)
    step = jax.jit(build_train_step(
        lambda p, b: gatedgcn.loss_fn(p, b, cfg), ocfg))

    rng = np.random.default_rng(7)
    t0 = time.perf_counter()
    for it in range(100):
        seeds = rng.integers(0, V, batch)
        blk = sampler.sample(seeds, fanouts)
        b = {
            "x": jnp.asarray(feats[blk.nodes]),
            "edge_attr": jnp.ones((len(blk.src), 1), jnp.float32),
            "src": jnp.asarray(blk.src), "dst": jnp.asarray(blk.dst),
            "labels": jnp.asarray(labels[blk.nodes].astype(np.int32)),
            "label_mask": jnp.zeros(len(blk.nodes)).at[blk.seeds].set(1.0),
        }
        params, opt_state, m = step(params, opt_state, b)
        if it % 20 == 0:
            print(f"  iter {it:3d}  loss {float(m['loss']):.4f}")
    print(f"100 sampled steps in {time.perf_counter()-t0:.1f}s "
          f"(block: {len(blk.nodes)} nodes / {len(blk.src)} edges)")


if __name__ == "__main__":
    main()
